"""Effect-serving demo: ingest a day, refresh, hot-swap, score a burst.

The full production loop on one host:

  day 1 arrives -> MomentStore.ingest -> refresh -> save (version 1)
  an EffectServer loads v1 from the checkpoint and serves traffic
  day 2 arrives -> ingest -> save (version 2)
  the server hot-swaps to v2 between waves (no request mixes versions),
  serves more traffic, then rolls back to v1 to show the escape hatch.

Run:  PYTHONPATH=src python examples/serve_effects_demo.py
"""
import tempfile

import numpy as np

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.config import CausalConfig
from repro.data.causal_dgp import make_causal_data
from repro.serve_effects import EffectServer, panel_from_checkpoint
from repro.store import MomentStore
from repro.sweep.spec import SweepSpec


def main():
    n_day, p, n_segments = 4096, 10, 8
    key = jax.random.PRNGKey(0)
    data = make_causal_data(key, 2 * n_day, p, effect=1.0,
                            discrete_treatment=False)
    sids = jax.random.randint(jax.random.fold_in(key, 1), (2 * n_day,),
                              0, n_segments)
    cfg = CausalConfig(n_folds=3, inference="none", row_block=1024,
                       nuisance_t="ridge", discrete_treatment=False,
                       cate_features=2)
    spec = SweepSpec(n_segments=n_segments, columns=(("dml", cfg),))

    with tempfile.TemporaryDirectory() as ckpt_dir:
        manager = CheckpointManager(ckpt_dir, keep_latest=4)

        # --- estimation side: the PR-8 daily ingest loop -------------
        store = MomentStore(spec, n_features=p, key=key)
        store.ingest(X=data.X[:n_day], y=data.y[:n_day],
                     t=data.t[:n_day], segment_ids=sids[:n_day])
        v1 = store.save(manager)
        print(f"day 1 ingested -> checkpoint version {v1}")

        # --- serving side: load v1, serve a burst --------------------
        panel = panel_from_checkpoint(manager, spec, p, key=key, step=v1)
        server = EffectServer(panel, wave_sizes=(8, 64), max_queue=256)
        burst_X = np.asarray(data.X[:128], np.float32)
        burst_sids = np.asarray(sids[:128])
        r1 = server.score(burst_X, burst_sids)
        print(f"served {len(r1)} requests on v{r1[0].version}: "
              f"first CATE {r1[0].cate:+.4f} "
              f"[{r1[0].lo:+.4f}, {r1[0].hi:+.4f}]")

        # --- day 2 arrives: ingest, snapshot, hot-swap ---------------
        store.ingest(X=data.X[n_day:], y=data.y[n_day:],
                     t=data.t[n_day:], segment_ids=sids[n_day:])
        v2 = store.save(manager)
        server.swap(panel_from_checkpoint(manager, spec, p, key=key,
                                          step=v2, store=store))
        r2 = server.score(burst_X, burst_sids)
        print(f"hot-swapped to v{r2[0].version}: "
              f"first CATE {r2[0].cate:+.4f} "
              f"(moved {r2[0].cate - r1[0].cate:+.5f} with day 2's rows)")

        # --- rollback: one reference assignment ----------------------
        server.rollback()
        r3 = server.score(burst_X[:8], burst_sids[:8])
        print(f"rolled back to v{r3[0].version}: "
              f"first CATE {r3[0].cate:+.4f} "
              f"(bitwise v1 again: {r3[0].cate == r1[0].cate})")

        # --- the per-server SLO metrics ------------------------------
        snap = server.snapshot()
        lat = snap["histograms"]["serve.request_seconds"]
        occ = snap["histograms"]["serve.batch_occupancy"]
        print(f"requests={snap['counters']['serve.requests']} "
              f"waves={snap['counters']['serve.waves']} "
              f"p50={lat['p50'] * 1e6:.0f}us p99={lat['p99'] * 1e6:.0f}us "
              f"mean_occupancy={occ['mean']:.2f}")


if __name__ == "__main__":
    main()
