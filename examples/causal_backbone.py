"""The Dream11 scenario (paper §4): users are described by EVENT
SEQUENCES, not tabular covariates.  An LM backbone (any of the 10
assigned archs) embeds each user's sequence; the pooled features become
the confounder set for fold-parallel DML.

Synthetic setup with known ground truth: a user's event sequence encodes
a latent 'engagement' score; engagement confounds both the treatment
(receiving a promo) and the outcome (deposits).  The true effect is 2.0.

    PYTHONPATH=src python examples/causal_backbone.py [--arch rwkv6-3b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.config import CausalConfig
from repro.configs import get_config
from repro.core.dml import DML
from repro.core.nuisance import backbone_features
from repro.models.model import build_model

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="rwkv6-3b",
                help="backbone family (smoke variant is used)")
ap.add_argument("--users", type=int, default=2048)
ap.add_argument("--seq", type=int, default=32)
args = ap.parse_args()

key = jax.random.PRNGKey(0)
cfg = get_config(args.arch + "-smoke")
model = build_model(cfg)
params = model.init(key)

# ---- synthetic user event sequences with a latent engagement factor ----
n, S = args.users, args.seq
ks = jax.random.split(key, 6)
engagement = jax.random.uniform(ks[0], (n,))  # in [0, 1)
# engaged users emit the "deposit-screen" event (token 7) more often;
# a mean-pooled embedding is then affine in engagement, so even an
# UNTRAINED backbone's features identify the confounder
special = jax.random.bernoulli(ks[1], engagement[:, None], (n, S))
rand_tok = jax.random.randint(ks[5], (n, S), 8, cfg.vocab_size)
tokens = jnp.where(special, 7, rand_tok).astype(jnp.int32)

prop = jax.nn.sigmoid(3.0 * (engagement - 0.5))
t = jax.random.bernoulli(ks[2], prop).astype(jnp.float32)
y = 2.0 * t + 4.0 * engagement + 0.5 * jax.random.normal(ks[3], (n,))

# ---- naive estimate is confounded ---------------------------------------
naive = float((y * t).sum() / t.sum() - (y * (1 - t)).sum() / (1 - t).sum())
print(f"naive difference-in-means  : {naive:+.3f}   (true effect +2.000)")

# ---- backbone features -> fold-parallel DML ------------------------------
print(f"embedding {n} user sequences with {args.arch} backbone ...")
feats = backbone_features(model, params, tokens, batch_size=256)
feats = (feats - feats.mean(0)) / (feats.std(0) + 1e-6)

cfg_c = CausalConfig(n_folds=5, nuisance_y="ridge", nuisance_t="logistic",
                     engine="parallel")
res = DML(cfg_c).fit(y, t, feats, key=key)
print(f"DML over backbone features : {res.ate:+.3f} "
      f"± {float(res.stderr[0]):.3f}")
print(res.summary())
