"""Bootstrap confidence intervals as ONE batched program.

EconML equivalent (the expensive path the paper's Ray translation
targets — B full re-estimations scheduled as tasks):

    est = LinearDML(...)
    est.fit(y, T, X=X, inference=BootstrapInference(n_bootstrap_samples=200))
    est.ate_interval(X)

Here the B replicates are weighted refits stacked on a replicate axis
and dispatched by the pluggable Executor (serial | vmap | shard_map) —
``vmap`` runs all 200 as one compiled program.

    PYTHONPATH=src python examples/inference_demo.py
"""
import jax

from repro.config import CausalConfig
from repro.core.dml import DML
from repro.data.causal_dgp import make_causal_data

key = jax.random.PRNGKey(0)
data = make_causal_data(jax.random.PRNGKey(42), 5_000, 10,
                        heterogeneous=True, effect=1.0)

cfg = CausalConfig(
    n_folds=5,
    cate_features=2,          # theta(x) = b0 + b1·x0
    inference="bootstrap",    # pairs bootstrap (multiplier|jackknife too)
    n_bootstrap=200,          # EconML's n_bootstrap_samples
    alpha=0.05,
    inference_executor="vmap",  # all 200 refits in ONE program
)

res = DML(cfg).fit(data.y, data.t, data.X, key=key)
print(f"true ATE      : {data.true_ate:+.4f}")
print(f"estimated ATE : {res.ate_of(data.X):+.4f}")

lo, hi = res.ate_interval()               # 200 vmapped replicates
print(f"bootstrap CI  : [{lo:+.4f}, {hi:+.4f}]  (percentile, B=200)")

jk = res.inference(method="jackknife")    # near-free: reuses fold fits
print(f"jackknife CI  : [{jk.ate_interval()[0]:+.4f}, "
      f"{jk.ate_interval()[1]:+.4f}]")
print(f"IF sandwich se: {float(res.stderr[0]):.4f}  "
      f"jackknife se: {float(jk.se[0]):.4f}  "
      f"bootstrap se: {float(res.inference().se[0]):.4f}")

# pointwise CATE bands at a few covariate profiles
Xq = data.X[:5]
band_lo, band_hi = res.cate_interval(Xq)
for i in range(5):
    print(f"CATE(x{i}): {float(res.cate(Xq)[i]):+.3f} in "
          f"[{float(band_lo[i]):+.3f}, {float(band_hi[i]):+.3f}]  "
          f"(true {float(data.true_cate[i]):+.3f})")
