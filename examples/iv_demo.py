"""Orthogonal-IV demo: when an unobserved confounder drives treatment,
DML is biased and an instrument rescues the estimand.

EconML equivalent (the estimators the paper's catalogue parallelizes
alongside DML):

    est = OrthoIV(...)                   # or DRIV(...)
    est.fit(y, T, Z=Z, X=X)
    est.ate_interval(X)

Here the three nuisances (E[Y|X], E[T|X], E[Z|X]) cross-fit through the
same fold-parallel engine as DML, the residual-on-residual 2SLS moment
comes off ONE instrumented streaming Gram, and the B bootstrap refits
run as one runtime-scheduled program.

    PYTHONPATH=src python examples/iv_demo.py
"""
import jax

from repro.config import CausalConfig
from repro.core.dml import DML
from repro.core.iv import DRIV, OrthoIV
from repro.core.refutation import weak_instrument
from repro.data.causal_dgp import make_iv_data

key = jax.random.PRNGKey(0)
data = make_iv_data(jax.random.PRNGKey(42), 8_000, 10,
                    effect=1.5, compliance=0.7)

cfg = CausalConfig(
    n_folds=5,
    nuisance_z="logistic",    # instrument model E[Z|X]
    inference="bootstrap",
    n_bootstrap=200,
    inference_executor="vmap",  # all 200 IV refits in ONE program
)

print(f"true LATE       : {data.true_late:+.4f}")

naive = DML(cfg).fit(data.y, data.t, data.X, key=key)
print(f"naive DML ATE   : {naive.ate:+.4f}   <- confounded (no instrument)")

res = OrthoIV(cfg).fit(data.y, data.t, data.z, data.X, key=key)
print(f"OrthoIV LATE    : {res.late:+.4f} ± {float(res.stderr[0]):.4f}")

lo, hi = res.late_interval()              # 200 vmapped replicates
print(f"bootstrap CI    : [{lo:+.4f}, {hi:+.4f}]  (percentile, B=200)")

jk = res.inference(method="jackknife")    # near-free: one segmented pass
print(f"jackknife CI    : [{jk.ate_interval()[0]:+.4f}, "
      f"{jk.ate_interval()[1]:+.4f}]")

dr = DRIV(cfg).fit(data.y, data.t, data.z, data.X, key=key)
print(f"DRIV LATE       : {dr.late:+.4f} ± {dr.stderr:.4f}")

print()
print(weak_instrument(res).row())
print()
print(res.summary())
