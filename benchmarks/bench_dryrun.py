"""Render the multi-pod dry-run roofline table (deliverable g) from the
JSONL records produced by ``repro.launch.dryrun``.

This is the "per-paper-table" bench for the scaling claim: the paper
reports wall-clock on a 5-node EC2 cluster; on a TPU target without
hardware we report the three per-chip roofline terms + the dominant
bottleneck per (arch x shape x mesh), which is the deployable-scale
equivalent."""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict

DEFAULT = os.path.join(os.path.dirname(__file__), "results",
                       "dryrun_final.jsonl")
FALLBACK = os.path.join(os.path.dirname(__file__), "results",
                        "dryrun_baseline.jsonl")


def load(path: str) -> Dict:
    rows = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            rows[(r["arch"], r["shape"], r["mesh"])] = r  # last write wins
    return rows


def render(rows: Dict, csv=print) -> None:
    csv("cell,us_per_call,derived")
    for (a, s, m), r in sorted(rows.items()):
        name = f"dryrun_{a}_{s}_{m}"
        if r["status"] == "skipped":
            csv(f"{name},0,skipped:{r['reason'][:40]}")
            continue
        if r["status"] != "ok":
            csv(f"{name},0,ERROR")
            continue
        csv(f"{name},{r['step_time']*1e6:.0f},"
            f"bneck={r['bottleneck']};mfu_bound={r['mfu_bound']*100:.2f}%;"
            f"useful={r['useful_frac']*100:.1f}%;"
            f"peak_gib={r['memory'].get('peak_bytes', 0)/2**30:.2f}")


def main(argv=None, csv=print):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="")
    args = ap.parse_args(argv)
    path = args.json or (DEFAULT if os.path.exists(DEFAULT) else FALLBACK)
    if not os.path.exists(path):
        csv("dryrun_table,0,missing (run: python -m repro.launch.dryrun "
            "--all --mesh both --json benchmarks/results/dryrun_final.jsonl)")
        return
    render(load(path), csv)


if __name__ == "__main__":
    main()
