"""Bench-regression gate: compare a BENCH_results.json run against the
committed BENCH_baseline.json and fail on slowdowns past the threshold.

Only entries whose name starts with a gated prefix participate
(crossfit / bootstrap / final_stage / iv / sweep / kernel_seg_gram /
store / serve — the perf wins this gate locks in); other entries are
informational.  A gated baseline
entry MISSING from the new results also fails: silently dropping a
benchmark is how regressions hide.

Baselines are machine-specific: absolute us_per_call tracks the host
that recorded it.  When CI runner hardware shifts, regenerate the
baseline from the bench-gate job's uploaded BENCH_results.json artifact
(commit it as BENCH_baseline.json) rather than widening the threshold.

Usage:
    python benchmarks/compare.py BENCH_baseline.json BENCH_results.json \
        [--threshold 1.20] [--prefixes crossfit,bootstrap,final_stage]
"""

from __future__ import annotations

import argparse
import json
import sys

GATED_PREFIXES = (
    "crossfit",
    "bootstrap",
    "final_stage",
    "iv",
    "sweep",
    "kernel_seg_gram",
    "store",
    "serve",
    "dist",
)


def load_entries(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    return {e["name"]: float(e["us_per_call"]) for e in payload["entries"]}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline")
    ap.add_argument("results")
    ap.add_argument(
        "--threshold",
        type=float,
        default=1.20,
        help="fail when new/old exceeds this (1.20 = +20%%)",
    )
    ap.add_argument(
        "--prefixes",
        default=",".join(GATED_PREFIXES),
        help="comma-separated gated name prefixes",
    )
    args = ap.parse_args(argv)

    base = load_entries(args.baseline)
    new = load_entries(args.results)
    prefixes = tuple(p for p in args.prefixes.split(",") if p)

    failures = []
    print(f"{'benchmark':<42} {'base_us':>12} {'new_us':>12} {'ratio':>7}")
    for name in sorted(base):
        if not name.startswith(prefixes):
            continue
        if name not in new:
            failures.append(f"{name}: missing from results")
            print(f"{name:<42} {base[name]:>12.0f} {'MISSING':>12}")
            continue
        ratio = new[name] / max(base[name], 1e-9)
        flag = " <-- REGRESSION" if ratio > args.threshold else ""
        print(
            f"{name:<42} {base[name]:>12.0f} {new[name]:>12.0f} "
            f"{ratio:>6.2f}x{flag}"
        )
        if ratio > args.threshold:
            failures.append(f"{name}: {ratio:.2f}x > {args.threshold:.2f}x")

    extra = sorted(n for n in new if n.startswith(prefixes) and n not in base)
    for name in extra:
        print(f"{name:<42} {'(new)':>12} {new[name]:>12.0f}")

    if failures:
        print(
            f"\nFAIL: {len(failures)} gated benchmark(s) regressed "
            f"beyond {args.threshold:.2f}x:",
            file=sys.stderr,
        )
        for f in failures:
            print(f"  {f}", file=sys.stderr)
        return 1
    print(f"\nOK: all gated benchmarks within {args.threshold:.2f}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
