"""Render the §Roofline table from the dry-run JSONL into
EXPERIMENTS_roofline.md (referenced by EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import json
import os

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "results", "dryrun_final.jsonl")
OUT = os.path.join(HERE, "..", "EXPERIMENTS_roofline.md")


def main():
    rows = {}
    with open(SRC) as f:
        for line in f:
            r = json.loads(line)
            rows[(r["arch"], r["shape"], r["mesh"])] = r  # last write wins
    lines = [
        "# §Roofline — generated table (single-pod 16x16 = 256 chips)",
        "",
        "Terms in ms/step per chip; `useful` = MODEL_FLOPS/(chips·HLO_FLOPs);",
        "`mfu≤` = MODEL_FLOPS/(chips·step·197TF).  Source: "
        "benchmarks/results/dryrun_final.jsonl.",
        "",
        "| arch | shape | compute | memory | collective | bottleneck | "
        "step ≥ | useful | mfu ≤ | peak GiB | note |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for (a, s, m), r in sorted(rows.items()):
        if m != "16x16":
            continue
        if r["status"] == "skipped":
            lines.append(f"| {a} | {s} | — | — | — | — | — | — | — | — | "
                         f"skipped: {r['reason'][:48]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {a} | {s} | ERROR |||||||||")
            continue
        peak = r["memory"].get("peak_bytes", 0) / 2**30
        note = ""
        if peak > 16:
            note = "needs ≥2 pods (v5e 16 GiB)"
        lines.append(
            f"| {a} | {s} | {r['t_compute']*1e3:.1f} | {r['t_memory']*1e3:.1f} "
            f"| {r['t_collective']*1e3:.1f} | {r['bottleneck']} "
            f"| {r['step_time']*1e3:.1f} | {r['useful_frac']*100:.0f}% "
            f"| {r['mfu_bound']*100:.2f}% | {peak:.1f} | {note} |")
    # multi-pod compile proof summary
    ok2 = sum(1 for (a, s, m), r in rows.items()
              if m == "2x16x16" and r["status"] == "ok")
    sk2 = sum(1 for (a, s, m), r in rows.items()
              if m == "2x16x16" and r["status"] == "skipped")
    lines += ["", f"Multi-pod (2x16x16 = 512 chips): {ok2} cells compile, "
              f"{sk2} skipped by spec, 0 errors (full records in the JSONL).",
              "",
              "Footnote: the dml-crossfit rows share one NOMINAL useful-flops"
              " estimate (K complement Grams + 16 IRLS Hessians), so the"
              " `useful`>100% on the parallel_loo engine simply states that"
              " the LOO identity does LESS arithmetic than the nominal"
              " algorithm — the point of the optimization."]
    with open(OUT, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {OUT} ({len(rows)} records)")


if __name__ == "__main__":
    main()
