"""Observability bench: ONE traced smoke run across the three execution
families — runtime-chunked bootstrap replicates, cross-fitting, and a
segment sweep — through a single ``repro.obs.Tracer``.

Deliverables (the paper's measurement story, made durable):

  * a Chrome trace-event JSON (``--trace``/``out_trace``; load it in
    Perfetto) whose span tree covers runtime chunks, sweep columns, and
    crossfit targets;
  * the predicted-vs-measured cost audit: every budget-scheduled chunk
    joined to its affine-memory-model prediction and its exact compiled
    HLO peak/roofline costs (the memory model that sizes chunks,
    validated by data);
  * an ``obs`` payload (span rollups + audit summary + metrics
    snapshot) that ``benchmarks/run.py`` embeds into
    ``BENCH_results.json``.

Entries are prefixed ``obs_`` — informational, not under the >20%
bench-regression gate (tracing is instrumentation, not a hot path).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.config import CausalConfig
from repro.core.crossfit import crossfit
from repro.core.dml import DML
from repro.core.nuisance import make_ridge
from repro.data.causal_dgp import make_causal_data
from repro.inference.bootstrap import make_dml_replicate_fn, replicate_keys
from repro.obs import Tracer
from repro.runtime import TaskRuntime, memory_model
from repro.sweep import SweepSpec, sweep

# the canonical contract shapes (see bench_runtime): auto-chunks <= ~8
# stay inside the verified serial == vmap bit-identity envelope
N, P, K = 2000, 8, 4


def run(B: int = 64, n: int = N, p: int = P, k: int = K,
        n_segments: int = 4, out_trace: str = "BENCH_trace.json",
        csv=print):
    tracer = Tracer()
    key = jax.random.PRNGKey(42)
    d = make_causal_data(key, n, p, effect=1.5)

    # -- 1. budget-chunked bootstrap through a traced runtime ----------
    est = DML(CausalConfig(n_folds=k))
    ctx = est.fit(d.y, d.t, d.X, key=jax.random.PRNGKey(0)).fit_ctx
    fn = make_dml_replicate_fn(ctx.nuis_y, ctx.nuis_t, k, with_se=False)
    args = (ctx.XW, ctx.y, ctx.t, ctx.phi)
    keys = replicate_keys(jax.random.PRNGKey(0x0B00), B)
    model = memory_model(fn, keys, args, B)
    assert model is not None and model.slope > 0
    # budget for ~6 replicates -> several chunks, several audit rows
    budget = int(model.base + 6.5 * model.slope)
    rt = TaskRuntime("vmap", memory_budget=budget, tracer=tracer)
    t0 = time.perf_counter()
    jax.block_until_ready(rt.map(fn, keys, *args, label="bootstrap")["theta"])
    t_boot = time.perf_counter() - t0

    # -- 2. crossfit through a traced runtime --------------------------
    folds_key, fit_key = jax.random.split(jax.random.PRNGKey(7))
    t0 = time.perf_counter()
    crossfit(make_ridge(), make_ridge(), fit_key, d.X, d.y, d.t,
             k, engine=TaskRuntime("vmap", tracer=tracer))
    t_cf = time.perf_counter() - t0

    # -- 3. segment sweep with labelled column spans -------------------
    sids = jax.random.randint(folds_key, (n,), 0, n_segments)
    cfg = CausalConfig(n_folds=k, inference="none")
    spec = SweepSpec(n_segments=n_segments, columns=(("dml", cfg),))
    t0 = time.perf_counter()
    panel = sweep(spec, X=d.X, y=d.y, t=d.t, segment_ids=sids,
                  key=jax.random.PRNGKey(3), executor="vmap", tracer=tracer)
    jax.block_until_ready(panel.columns[0].thetas)
    t_sweep = time.perf_counter() - t0

    if out_trace:
        tracer.write_chrome_trace(out_trace)
        csv(f"# obs: wrote Chrome trace ({len(tracer.spans)} spans) "
            f"-> {out_trace}")
    csv("# obs: cost audit (predicted vs measured per chunk)")
    for line in tracer.audit.table().splitlines():
        csv(f"# {line}")

    csv(f"obs_traced_bootstrap_n{n}_B{B},{t_boot*1e6:.0f},"
        f"audit_chunks={len(tracer.audit)}")
    csv(f"obs_traced_crossfit_n{n}_k{k},{t_cf*1e6:.0f},traced")
    csv(f"obs_traced_sweep_n{n}_E{n_segments},{t_sweep*1e6:.0f},traced")

    return {
        "trace_file": out_trace or None,
        "n_spans": len(tracer.spans),
        "spans": tracer.rollup(),
        "audit": {
            "summary": tracer.audit.summary(),
            "rows": tracer.audit.as_dicts(),
        },
        "metrics": tracer.metrics.snapshot(),
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--B", type=int, default=64)
    ap.add_argument("--trace", default="BENCH_trace.json",
                    help="Chrome trace output path ('' disables)")
    args = ap.parse_args(argv)
    payload = run(B=args.B, out_trace=args.trace)
    print(f"# obs rollup: {payload['spans']}")


if __name__ == "__main__":
    main()
