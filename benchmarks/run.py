"""Benchmark aggregator: one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines."""
from __future__ import annotations

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-exact scales (1M x 500; slow on CPU)")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")

    print("# --- paper Fig. 6: DML vs DML_Ray crossfit runtime ---")
    from benchmarks import bench_crossfit
    if args.full:
        bench_crossfit.run(sizes=(10_000, 100_000, 1_000_000), p=500)
    else:
        bench_crossfit.run(sizes=(10_000, 30_000, 100_000), p=50)

    print("# --- paper Fig. 5 / 5.2: distributed tuning ---")
    from benchmarks import bench_tuning
    bench_tuning.run(n=20_000, p=50, n_trials=8, n_folds=5)

    print("# --- bootstrap inference: serial vs batched executor ---")
    from benchmarks import bench_inference
    if args.full:
        bench_inference.run(sizes=(10_000, 100_000), p=500, B=200)
    else:
        bench_inference.run(sizes=(5_000, 10_000), p=20, B=32)

    print("# --- kernel micro-benchmarks ---")
    from benchmarks import bench_kernels
    bench_kernels.main()

    print("# --- multi-pod dry-run roofline (deliverable e/g) ---")
    from benchmarks import bench_dryrun
    bench_dryrun.main([])


if __name__ == "__main__":
    main()
