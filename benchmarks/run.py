"""Benchmark aggregator: one section per paper table/figure.
Prints ``name,us_per_call,derived`` CSV lines AND writes a
standardized ``BENCH_results.json`` (override with --json) so the
bench trajectory is machine-readable across PRs:

    {"meta": {...}, "entries": [
        {"name": ..., "us_per_call": ..., "derived": ...}, ...]}
"""
from __future__ import annotations

import argparse
import json
import pathlib
import platform
import sys
import time

# make `python benchmarks/run.py` work from any cwd: the repo root
# provides the `benchmarks` package, src/ provides `repro` when the
# package isn't pip-installed
_ROOT = pathlib.Path(__file__).resolve().parent.parent
for _p in (str(_ROOT), str(_ROOT / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)


class Recorder:
    """print-compatible sink that also parses the CSV lines into
    standardized JSON entries."""

    def __init__(self):
        self.entries = []

    def __call__(self, line: str):
        print(line)
        if not line or line.startswith("#"):
            return
        parts = line.split(",", 2)
        if len(parts) < 2:
            return
        try:
            us = float(parts[1])
        except ValueError:
            return
        self.entries.append({
            "name": parts[0],
            "us_per_call": us,
            "derived": parts[2] if len(parts) > 2 else "",
        })


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-exact scales (1M x 500; slow on CPU)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced fixed-size subset for the CI "
                         "bench-gate: crossfit/inference/final_stage/"
                         "runtime/obs only, minutes not tens of minutes")
    ap.add_argument("--json", default="BENCH_results.json",
                    help="output path for the standardized bench JSON "
                         "('' disables)")
    ap.add_argument("--outdir", default="bench_out",
                    help="directory for bench side artifacts (the "
                         "Chrome trace) — keeps the repo root clean")
    args = ap.parse_args(argv)
    pathlib.Path(args.outdir).mkdir(parents=True, exist_ok=True)

    rec = Recorder()
    t0 = time.time()
    print("name,us_per_call,derived")

    print("# --- paper Fig. 6: DML vs DML_Ray crossfit runtime ---")
    from benchmarks import bench_crossfit
    if args.full:
        bench_crossfit.run(sizes=(10_000, 100_000, 1_000_000), p=500,
                           csv=rec)
    elif args.smoke:
        bench_crossfit.run(sizes=(5_000, 10_000), p=20, csv=rec)
    else:
        bench_crossfit.run(sizes=(10_000, 30_000, 100_000), p=50, csv=rec)

    if not args.smoke:
        print("# --- paper Fig. 5 / 5.2: distributed tuning ---")
        from benchmarks import bench_tuning
        bench_tuning.run(n=20_000, p=50, n_trials=8, n_folds=5, csv=rec)

    print("# --- bootstrap inference: serial vs batched executor ---")
    from benchmarks import bench_inference
    if args.full:
        bench_inference.run(sizes=(10_000, 100_000), p=500, B=200, csv=rec)
    elif args.smoke:
        bench_inference.run(sizes=(5_000,), p=20, B=16, csv=rec)
    else:
        bench_inference.run(sizes=(5_000, 10_000), p=20, B=32, csv=rec)

    print("# --- orthogonal-IV family: OrthoIV/DRIV fits + bootstrap ---")
    from benchmarks import bench_iv
    if args.full:
        bench_iv.run(sizes=(10_000, 100_000), p=500, B=200, csv=rec)
    elif args.smoke:
        bench_iv.run(sizes=(5_000,), p=20, B=16, csv=rec)
    else:
        bench_iv.run(sizes=(5_000, 10_000), p=20, B=32, csv=rec)

    print("# --- streaming moments: chunked vs whole final stage ---")
    from benchmarks import bench_final_stage
    if args.full:
        bench_final_stage.run(n=1_000_000, p=50, p_phi=4, row_block=8192,
                              csv=rec)
    else:
        bench_final_stage.run(csv=rec)

    print("# --- task runtime: memory-budgeted chunked scheduling ---")
    from benchmarks import bench_runtime
    if args.smoke:
        bench_runtime.run(B=200, csv=rec)
    else:
        bench_runtime.run(B=2000, csv=rec)

    print("# --- segment sweep: serial loop vs batched panel (E=64) ---")
    from benchmarks import bench_sweep
    if args.full:
        bench_sweep.run(n=65_536, p=50, n_folds=5, csv=rec)
    elif args.smoke:
        bench_sweep.run(n=8192, csv=rec)
    else:
        bench_sweep.run(csv=rec)

    print("# --- fused segment-Gram kernel vs one-hot einsum ---")
    from benchmarks import bench_seg_gram
    if args.full:
        bench_seg_gram.run(n=65_536, csv=rec)
    elif args.smoke:
        bench_seg_gram.run(n=8192, csv=rec)
    else:
        bench_seg_gram.run(csv=rec)

    print("# --- effect store: incremental ingest vs full refit ---")
    from benchmarks import bench_store
    if args.full:
        bench_store.run(n_day=16_384, days=5, p=20, csv=rec)
    elif args.smoke:
        bench_store.run(n_day=2048, days=3, csv=rec)
    else:
        bench_store.run(csv=rec)

    print("# --- effect serving: wave-batched scoring latency/QPS ---")
    from benchmarks import bench_serve
    if args.full:
        bench_serve.run(n_requests=4096, wave=256, n_day=16_384, p=20,
                        n_segments=64, csv=rec)
    elif args.smoke:
        bench_serve.run(n_requests=256, wave=64, n_day=2048, csv=rec)
    else:
        bench_serve.run(csv=rec)

    print("# --- observability: traced smoke run + cost audit ---")
    from benchmarks import bench_obs
    trace_path = str(pathlib.Path(args.outdir) / "BENCH_trace.json")
    if args.smoke:
        obs_payload = bench_obs.run(B=32, csv=rec, out_trace=trace_path)
    else:
        obs_payload = bench_obs.run(csv=rec, out_trace=trace_path)

    print("# --- distributed: row-sharded sweep + store over 8 devices ---")
    # Runs in a SUBPROCESS: the forced host-platform device count must
    # not leak into this process (jax pins the device count at first
    # init, and every other section benches the 1-device baseline the
    # >20% gate was recorded against).
    from benchmarks import bench_distributed
    bench_distributed.run_subprocess(
        csv=rec, smoke=bool(args.smoke or not args.full))

    if not args.smoke:
        print("# --- kernel micro-benchmarks ---")
        from benchmarks import bench_kernels
        bench_kernels.main(csv=rec)

        print("# --- multi-pod dry-run roofline (deliverable e/g) ---")
        from benchmarks import bench_dryrun
        bench_dryrun.main([], csv=rec)

    if args.json:
        import jax
        payload = {
            "meta": {
                "schema": "bench-v1",
                "unix_time": int(t0),
                "wall_seconds": round(time.time() - t0, 1),
                "full": bool(args.full),
                "smoke": bool(args.smoke),
                "backend": jax.default_backend(),
                "platform": platform.platform(),
            },
            "entries": rec.entries,
            # span rollups + predicted-vs-measured audit + metrics from
            # the traced smoke run (benchmarks/bench_obs; informational,
            # not under the bench gate)
            "obs": obs_payload,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {len(rec.entries)} entries -> {args.json}")


if __name__ == "__main__":
    main()
