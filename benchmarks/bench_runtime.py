"""Task-runtime benchmark: memory-aware chunked replicate scheduling.

The acceptance demo for repro.runtime: ``n_bootstrap=2000`` bootstrap
replicates at a (n, B) scale where the ONE-vmap path's predicted peak
memory (the affine model probed from compiled HLO, launch.hlo_cost)
exceeds the configured per-device budget by ~two orders of magnitude —
the scheduler streams the replicate axis in budget-sized chunks
instead, and the result is bit-identical per replicate to the serial
and small-vmap runs (the replicate-invariance contract of
inference/numerics, asserted here at the same canonical shapes the
test suite pins it at — XLA's contraction tiling is shape-dependent,
so the contract is a per-shape property, not a universal one).

Entries:
  runtime_serial_*    extrapolated Ray-less loop baseline (per-rep × B)
  runtime_chunked_*   the budgeted chunked run (the paper's streaming
                      claim), derived column carries chunk size +
                      predicted peak vs budget
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import CausalConfig
from repro.core.dml import DML
from repro.data.causal_dgp import make_causal_data
from repro.inference.bootstrap import make_dml_replicate_fn, replicate_keys
from repro.runtime import TaskRuntime, memory_model

# the canonical shapes tests/test_inference.py + test_runtime.py pin the
# serial == vmap bit-identity contract at (batch sizes <= ~8 hold; XLA
# retiles the n-contraction above that, so the budget below is chosen to
# keep the auto-chunk inside the verified envelope)
N, P, K = 3000, 8, 4


def run(B: int = 2000, budget_bytes: int = 3 * 2 ** 20, n: int = N,
        p: int = P, k: int = K, check: int = 5, csv=print):
    key = jax.random.PRNGKey(42)
    d = make_causal_data(key, n, p, effect=1.5)
    est = DML(CausalConfig(n_folds=k))
    ctx = est.fit(d.y, d.t, d.X, key=jax.random.PRNGKey(0)).fit_ctx
    fn = make_dml_replicate_fn(ctx.nuis_y, ctx.nuis_t, k, with_se=False)
    args = (ctx.XW, ctx.y, ctx.t, ctx.phi)
    keys = replicate_keys(jax.random.PRNGKey(0x0b00), B)

    model = memory_model(fn, keys, args, B)
    assert model is not None and model.slope > 0
    peak_full = model.peak(B)
    assert peak_full > budget_bytes, (
        f"demo needs the un-chunked path over budget: predicted "
        f"{peak_full/2**20:.0f}MiB <= {budget_bytes/2**20:.0f}MiB")

    rt = TaskRuntime("vmap", memory_budget=budget_bytes)
    chunk, _ = rt.plan_chunk(fn, keys, args, B)
    assert chunk < B

    # warm the two chunk programs (full chunk + remainder) so the
    # measurement isolates the scheduling mechanism, not XLA compile
    # time — same methodology as bench_inference
    jax.block_until_ready(rt.map(fn, keys[: 2 * chunk + B % chunk], *args)["theta"])
    t0 = time.perf_counter()
    out = rt.map(fn, keys, *args)["theta"]
    jax.block_until_ready(out)
    t_chunked = time.perf_counter() - t0

    # serial baseline on a prefix (extrapolated — the full serial run is
    # the same work B/check times over); warmed so the baseline measures
    # dispatch, not compile (same methodology as bench_inference)
    rs = TaskRuntime("serial")
    ser = rs.map(fn, keys[:check], *args)["theta"]
    jax.block_until_ready(ser)
    t0 = time.perf_counter()
    jax.block_until_ready(rs.map(fn, keys[:check], *args)["theta"])
    t_serial_rep = (time.perf_counter() - t0) / check

    # bit-identity: serial == one-vmap == chunk-prefix, per replicate
    vm = TaskRuntime("vmap").map(fn, keys[:check], *args)["theta"]
    a_ser, a_vm = np.asarray(ser), np.asarray(vm)
    a_ck = np.asarray(out)[:check]
    assert np.array_equal(a_ser, a_vm), "serial != vmap bitwise"
    assert np.array_equal(a_ser, a_ck), "serial != chunked bitwise"

    t_serial = t_serial_rep * B
    csv(f"runtime_serial_n{n}_B{B},{t_serial*1e6:.0f},"
        f"extrapolated_from_{check}_reps")
    csv(f"runtime_chunked_n{n}_B{B},{t_chunked*1e6:.0f},"
        f"chunk={chunk} peak_pred={peak_full/2**20:.0f}MiB"
        f">budget={budget_bytes/2**20:.0f}MiB "
        f"speedup={t_serial/t_chunked:.2f}x identity=PASS")
    return t_serial, t_chunked, chunk


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--B", type=int, default=2000,
                    help="bootstrap replicates (acceptance scale)")
    ap.add_argument("--budget-mb", type=float, default=3.0,
                    help="per-device memory budget (MiB)")
    args = ap.parse_args(argv)
    run(B=args.B, budget_bytes=int(args.budget_mb * 2 ** 20))


if __name__ == "__main__":
    main()
