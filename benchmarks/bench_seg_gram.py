"""Fused segment-Gram kernel micro-benchmark (repro.kernels.seg_gram).

The segmented sweep's hot shape: a fold-segmented augmented Gram over
the combined id ``segment*K + fold`` (S = E*K segments, q design
columns).  Baseline is the one-hot einsum the moments engine lowers to
by default (``'ns,ni,nj->sij'`` — materializes the (n, S) mask);
against it, the dispatch-default fused lowering (XLA scatter on CPU,
the Pallas kernel on TPU), which never builds the mask.

Names carry the ``kernel_seg_gram`` prefix (gated in
benchmarks/compare.py — the fused path must not regress).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.kernels.seg_gram import ops as sg_ops


def _time(fn, reps=5):
    fn()  # warm-up/compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(n=16_384, q=12, n_segments=192, row_block=1024, csv=print, reps=5):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    U = jax.random.normal(ks[0], (n, q), jnp.float32)
    seg = jax.random.randint(ks[1], (n,), 0, n_segments)
    w = jax.random.exponential(ks[2], (n,)).astype(jnp.float32)
    tag = f"n{n}_q{q}_S{n_segments}"

    @jax.jit
    def onehot(U, seg, w):
        oh = jax.nn.one_hot(seg, n_segments, dtype=jnp.float32)
        return jnp.einsum("ns,n,ni,nj->sij", oh, w, U, U)

    fused = jax.jit(lambda U, seg, w: sg_ops.segment_outer(
        U, U, seg, n_segments, w=w, row_block=row_block))

    t_oh = _time(lambda: jax.block_until_ready(onehot(U, seg, w)), reps)
    t_fused = _time(lambda: jax.block_until_ready(fused(U, seg, w)), reps)
    csv(f"kernel_seg_gram_onehot_{tag},{t_oh*1e6:.0f},baseline")
    csv(f"kernel_seg_gram_{sg_ops.default_backend()}_{tag},"
        f"{t_fused*1e6:.0f},speedup={t_oh/max(t_fused, 1e-12):.2f}x")

    # The two newest fused builders (fold_weighted_gram's dense (k, n)
    # weight pass and the logistic Newton step's gram+vec), chunked
    # moments-engine baseline vs the seg_gram lowering — the forms that
    # used to take the pallas→chunked fallback rung.
    from repro.core import moments

    k = 4
    X = U[:, : max(1, q - 1)]
    Wk = jax.random.exponential(jax.random.split(key, 4)[3],
                                (k, n)).astype(jnp.float32)
    v = jax.random.normal(key, (n,), jnp.float32)
    forms = {
        "fold_weighted": lambda strat: jax.jit(
            lambda X, Wk: moments.fold_weighted_gram(
                X, Wk, intercept=True, row_block=row_block,
                strategy=strat)[0]),
        "gram_and_vec": lambda strat: jax.jit(
            lambda X, w, v: moments.weighted_gram_and_vec(
                X, w, v, intercept=True, row_block=row_block,
                strategy=strat)[0]),
    }
    out = {"onehot": t_oh, "fused": t_fused}
    for name, mk in forms.items():
        args = (X, Wk) if name == "fold_weighted" else (X, w, v)
        t_c = _time(lambda: jax.block_until_ready(mk("chunked")(*args)),
                    reps)
        t_p = _time(lambda: jax.block_until_ready(mk("pallas")(*args)),
                    reps)
        csv(f"kernel_seg_gram_{name}_chunked_{tag},{t_c*1e6:.0f},baseline")
        csv(f"kernel_seg_gram_{name}_{sg_ops.default_backend()}_{tag},"
            f"{t_p*1e6:.0f},speedup={t_c/max(t_p, 1e-12):.2f}x")
        out[name] = t_p
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="sweep-scale rows (n=65536)")
    args = ap.parse_args(argv)
    if args.full:
        run(n=65_536)
    else:
        run()


if __name__ == "__main__":
    main()
