"""Kernel micro-benchmarks: wall time of the jnp reference paths on this
host (the Pallas variants are TPU-target; their interpret-mode execution
measures Python, not hardware, so we report the ref path + derived
bandwidth/intensity numbers that feed the §Roofline discussion)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.residual_gram import ops as rg_ops
from repro.kernels.ssm_scan import ops as gla_ops


def _timeit(f, *args, reps=5):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def bench_flash(csv=print):
    B, S, H, KV, D = 1, 1024, 8, 2, 64
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, S, H, D), jnp.float32)
    k = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    v = jax.random.normal(key, (B, S, KV, D), jnp.float32)
    f = jax.jit(lambda q, k, v: fa_ops.flash_attention(q, k, v, causal=True,
                                                       backend="ref"))
    t = _timeit(f, q, k, v)
    flops = 4 * B * H * S * S * D  # qk + pv
    csv(f"flash_attention_ref_S{S},{t*1e6:.0f},gflops={flops/t/1e9:.1f}")


def bench_gla(csv=print):
    B, H, T, Dk, Dv = 1, 8, 2048, 64, 64
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (B, H, T, Dk))
    k = jax.random.normal(key, (B, H, T, Dk))
    v = jax.random.normal(key, (B, H, T, Dv))
    w = 0.5 + 0.5 * jax.random.uniform(key, (B, H, T, Dk))
    f = jax.jit(lambda *a: gla_ops.gla(*a, chunk=16)[0])
    t = _timeit(f, q, k, v, w)
    csv(f"gla_scan_ref_T{T},{t*1e6:.0f},tokens_per_s={B*T/t:.0f}")


def bench_residual_gram(csv=print):
    n, p = 200_000, 128
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    y, t_, my, mt = (jax.random.normal(ks[i], (n,)) for i in range(4))
    phi = jax.random.normal(ks[4], (n, p))
    f = jax.jit(lambda *a: rg_ops.residual_gram(*a, backend="ref"))
    dt = _timeit(f, y, t_, my, mt, phi)
    bytes_moved = n * p * 4  # one streaming pass over phi
    csv(f"residual_gram_ref_n{n}_p{p},{dt*1e6:.0f},"
        f"stream_gbps={bytes_moved/dt/1e9:.2f}")


def main(csv=print):
    bench_flash(csv)
    bench_gla(csv)
    bench_residual_gram(csv)


if __name__ == "__main__":
    main()
