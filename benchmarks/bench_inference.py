"""Bootstrap inference runtime: sequential-loop baseline vs batched
executor — the Fig.-6-style mechanism comparison for the THIRD iterative
step class (after bench_crossfit's fold fits and bench_tuning's trials).

EconML's ``BootstrapInference(B)`` re-runs the estimator B times; Ray
schedules those as B tasks.  On one host the translation is the
Executor: ``serial`` dispatches B separate programs (the Ray-less
baseline), ``vmap`` stacks the B weighted refits into ONE compiled
program, ``shard_map`` additionally shards the replicate axis over the
device mesh.  The speedup isolates dispatch overhead + compile reuse +
shared data passes, the same mechanism the paper measures.

Defaults are CPU-friendly; ``--full`` runs a paper-scale sweep.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.config import CausalConfig
from repro.core.dml import DML
from repro.data.causal_dgp import make_causal_data
from repro.inference import make_executor
from repro.inference.bootstrap import make_dml_replicate_fn, replicate_keys


def time_bootstrap(ctx, n_folds: int, B: int, executor: str,
                   key, reps: int = 1) -> float:
    """Wall-clock for B bootstrap replicates through one executor.  The
    replicate closure is built once and warmed up, so the measurement
    isolates the paper's mechanism — B dispatched programs vs one
    batched program — not XLA compile time (same methodology as
    bench_crossfit's warm-up)."""
    exe = make_executor(executor)
    fn = make_dml_replicate_fn(ctx.nuis_y, ctx.nuis_t, n_folds,
                               with_se=False)
    keys = replicate_keys(key, B)

    def run():
        jax.block_until_ready(
            exe.map(fn, keys, ctx.XW, ctx.y, ctx.t, ctx.phi)["theta"])

    run()  # warm-up/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        run()
    return (time.perf_counter() - t0) / reps


def run(sizes, p, B=64, n_folds=5, key=None, csv=print):
    key = key if key is not None else jax.random.PRNGKey(0)
    rows = []
    for n in sizes:
        data = make_causal_data(jax.random.fold_in(key, n), n, p,
                                effect=1.0)
        est = DML(CausalConfig(n_folds=n_folds))
        ctx = est.fit(data.y, data.t, data.X, key=key).fit_ctx
        kb = jax.random.fold_in(key, 0x0b00)
        t_seq = time_bootstrap(ctx, n_folds, B, "serial", kb)
        t_vec = time_bootstrap(ctx, n_folds, B, "vmap", kb)
        t_shm = time_bootstrap(ctx, n_folds, B, "shard_map", kb)
        csv(f"bootstrap_seq_n{n}_p{p}_B{B},{t_seq*1e6:.0f},baseline")
        csv(f"bootstrap_vmap_n{n}_p{p}_B{B},{t_vec*1e6:.0f},speedup="
            f"{t_seq/t_vec:.2f}x")
        csv(f"bootstrap_shard_n{n}_p{p}_B{B},{t_shm*1e6:.0f},speedup="
            f"{t_seq/t_shm:.2f}x")
        rows.append((n, t_seq, t_vec, t_shm))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale n sweep with B=200")
    args = ap.parse_args(argv)
    if args.full:
        run(sizes=(10_000, 100_000), p=500, B=200)
    else:
        run(sizes=(5_000, 10_000), p=20, B=32)


if __name__ == "__main__":
    main()
