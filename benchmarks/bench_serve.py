"""Online effect-serving: p50/p99 request latency + throughput under
wave batching — the production workload the estimation side feeds
(Netflix "Computational Causal Inference": serving effects to product
traffic is a first-class workload, not a by-product of fitting).

Three gated measurements of the same store-fed panel:

  serve_wave          one full admission wave at the largest jit shape
                      (submit `wave` requests, pad, score, fill
                      responses) — the steady-state serving cost.  The
                      derived column reports p50/p99 request latency
                      and throughput over a sustained fixed-rate burst
                      run, and asserts identity=PASS: batched wave
                      outputs are bitwise equal to per-request
                      unbatched scoring;
  serve_single_req    the same requests served one-per-wave
                      (wave_sizes=(1,)) — the per-request floor the
                      batch amortizes; derived shows the batch
                      speedup;
  serve_hot_swap      loading a refreshed panel version from a
                      MomentStore checkpoint (restore + refresh +
                      prepare) and swapping it in — the store -> serve
                      edge; derived confirms the served version
                      advanced.
"""
from __future__ import annotations

import argparse
import tempfile
import time

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.config import CausalConfig
from repro.data.causal_dgp import make_causal_data
from repro.serve_effects import (
    EffectServer,
    ServingPanel,
    panel_from_checkpoint,
    score_single,
)
from repro.store import MomentStore
from repro.sweep.spec import SweepSpec


def _timeit(fn, reps: int = 3) -> float:
    fn()  # warm-up/compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(n_requests=512, wave=64, n_day=2048, p=10, n_segments=8,
        n_folds=3, row_block=512, key=None, csv=print, reps=3):
    """Benchmark serving a store-fed panel at ``wave``-sized waves."""
    key = key if key is not None else jax.random.PRNGKey(0)
    data = make_causal_data(jax.random.fold_in(key, 7), 2 * n_day, p,
                            effect=1.0, discrete_treatment=False)
    sids = jax.random.randint(jax.random.fold_in(key, 1), (2 * n_day,),
                              0, n_segments)
    cfg = CausalConfig(n_folds=n_folds, inference="none",
                      row_block=row_block, nuisance_t="ridge",
                      discrete_treatment=False, cate_features=2)
    spec = SweepSpec(n_segments=n_segments, columns=(("dml", cfg),))
    tag = f"w{wave}_R{n_requests}_p{p}_E{n_segments}"

    store = MomentStore(spec, n_features=p, key=key)
    store.ingest(X=data.X[:n_day], y=data.y[:n_day], t=data.t[:n_day],
                 segment_ids=sids[:n_day])
    panel_v1 = ServingPanel.from_effect_panel(
        store.refresh(), n_features=p, version=store.version)

    rng = np.random.default_rng(0)
    req_X = np.asarray(data.X[:n_requests], np.float32)
    req_sids = rng.integers(0, n_segments, n_requests)

    # --- one full wave at the jit shape (steady-state cost) ----------
    srv = EffectServer(panel_v1, wave_sizes=(wave,),
                       max_queue=max(2 * wave, n_requests))

    def one_wave():
        for i in range(wave):
            srv.submit(req_X[i], int(req_sids[i]))
        srv.step()

    t_wave = _timeit(one_wave, reps)

    # --- sustained fixed-rate burst run: latency SLOs + throughput ---
    srv_run = EffectServer(panel_v1, wave_sizes=(wave,),
                           max_queue=max(2 * wave, n_requests))
    # the (wave, p) jit shape is already warm from the timed waves above
    t0 = time.perf_counter()
    for lo in range(0, n_requests, wave):  # one burst per wave period
        for i in range(lo, min(lo + wave, n_requests)):
            srv_run.submit(req_X[i], int(req_sids[i]))
        srv_run.step()
    srv_run.drain()
    elapsed = time.perf_counter() - t0
    lat = srv_run.snapshot()["histograms"]["serve.request_seconds"]
    qps = n_requests / elapsed

    # --- bitwise: batched waves == per-request unbatched scoring -----
    responses = srv_run.score(req_X[:wave], req_sids[:wave])
    identity = "PASS"
    for i, r in enumerate(responses):
        ref = jax.block_until_ready(
            score_single(panel_v1, req_X[i], int(req_sids[i]), srv_run._z))
        if r.cate != float(ref["cate"]) or r.ok != bool(ref["ok"]):
            identity = "FAIL"
            break

    csv(f"serve_wave_{tag},{t_wave * 1e6:.1f},"
        f"p50={lat['p50'] * 1e6:.0f}us_p99={lat['p99'] * 1e6:.0f}us_"
        f"qps={qps:.0f} identity={identity}")

    # --- per-request floor: one request per wave ---------------------
    srv1 = EffectServer(panel_v1, wave_sizes=(1,), max_queue=2 * wave)

    def single_req():
        srv1.submit(req_X[0], int(req_sids[0]))
        srv1.step()

    t_single = _timeit(single_req, reps)
    csv(f"serve_single_req_{tag},{t_single * 1e6:.1f},"
        f"batch_amortization={wave * t_single / max(t_wave, 1e-9):.1f}x"
        f"_at_w{wave}")

    # --- hot-swap from a refreshed store checkpoint ------------------
    with tempfile.TemporaryDirectory() as ckpt_dir:
        manager = CheckpointManager(ckpt_dir, keep_latest=4)
        store.save(manager)
        store.ingest(X=data.X[n_day:], y=data.y[n_day:],
                     t=data.t[n_day:], segment_ids=sids[n_day:])
        v2 = store.save(manager)

        shell = MomentStore(spec, n_features=p, key=key)  # warm shell

        def hot_swap():
            fresh = panel_from_checkpoint(manager, spec, p, key=key,
                                          step=v2, store=shell)
            srv.swap(fresh)

        t_swap = _timeit(hot_swap, reps)
        csv(f"serve_hot_swap_{tag},{t_swap * 1e6:.1f},"
            f"served_version={srv.version} (restore+refresh+install)")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--wave", type=int, default=64)
    ap.add_argument("--p", type=int, default=10)
    ap.add_argument("--segments", type=int, default=8)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(n_requests=args.requests, wave=args.wave, p=args.p,
        n_segments=args.segments)
