"""Paper Fig. 6: DML (sequential EconML-style) vs DML_Ray (parallel)
runtime at growing data scales.

On this host the mesh is one CPU device, so the measured speedup isolates
the paper's MECHANISM — K sequential fit programs vs one batched
fold-parallel program (dispatch overhead, compile reuse, shared data
passes) — rather than multi-node scaling, which the dry-run covers
(benchmarks/bench_dryrun.py renders the 256-chip roofline for the same
workload).

Defaults are CPU-friendly; ``--full`` runs the paper's exact
10k/100k/1M x 500 sweep.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.config import CausalConfig
from repro.core.dml import DML
from repro.data.causal_dgp import make_causal_data


def time_fit(est: DML, data, key, reps: int = 1) -> float:
    # warm-up/compile
    est.fit(data.y, data.t, data.X, key=key)
    t0 = time.perf_counter()
    for _ in range(reps):
        res = est.fit(data.y, data.t, data.X, key=key)
        jax.block_until_ready(res.theta)
    return (time.perf_counter() - t0) / reps


def run(sizes, p, n_folds=5, key=None, csv=print):
    key = key if key is not None else jax.random.PRNGKey(0)
    rows = []
    for n in sizes:
        data = make_causal_data(jax.random.fold_in(key, n), n, p,
                                effect=1.0)
        seq = DML(CausalConfig(n_folds=n_folds, engine="sequential"))
        par = DML(CausalConfig(n_folds=n_folds, engine="parallel"))
        loo = DML(CausalConfig(n_folds=n_folds, engine="parallel_loo"))
        t_seq = time_fit(seq, data, key)
        t_par = time_fit(par, data, key)
        t_loo = time_fit(loo, data, key)
        csv(f"crossfit_seq_n{n}_p{p},{t_seq*1e6:.0f},ate_err="
            f"{abs(seq.fit(data.y, data.t, data.X, key=key).ate-1):.4f}")
        csv(f"crossfit_par_n{n}_p{p},{t_par*1e6:.0f},speedup="
            f"{t_seq/t_par:.2f}x")
        csv(f"crossfit_loo_n{n}_p{p},{t_loo*1e6:.0f},speedup="
            f"{t_seq/t_loo:.2f}x")
        rows.append((n, t_seq, t_par, t_loo))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-exact 10k/100k/1M x 500")
    args = ap.parse_args(argv)
    if args.full:
        run(sizes=(10_000, 100_000, 1_000_000), p=500)
    else:
        run(sizes=(10_000, 30_000, 100_000), p=50)


if __name__ == "__main__":
    main()
