"""Chunked vs whole-array final stage: the memory/runtime trade of the
streaming moments engine (repro.core.moments) on the DML final-stage
hot spot.

The whole-array path materializes the dense (n, p_phi) moment matrix
Z = rt ⊙ phi (plus its HC0 meat pass); the chunked path lax.scans row
blocks so peak temporaries are O(row_block · p_phi).  On one host the
interesting number is the runtime cost of streaming (it buys bounded
memory, not speed); the peak-temp claim itself is asserted by
tests/test_moments.py against the post-optimization HLO.  The third
column, ``strategy="pallas"``, streams the same two passes through the
fused seg_gram lowerings — the measured path that closes (and on CPU
reverses) the chunked-vs-whole runtime gap.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.final_stage import cate_basis, fit_final_stage
from repro.data.causal_dgp import make_causal_data


def _time(fn, reps=3):
    fn()  # warm-up/compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def run(n=100_000, p=20, p_phi=4, row_block=4096, csv=print):
    key = jax.random.PRNGKey(0)
    d = make_causal_data(key, n, p, effect=1.0)
    my = 0.1 * d.y
    mt = jnp.full((n,), 0.5, jnp.float32)
    phi = cate_basis(d.X, p_phi)

    jitted = {(rb, st): jax.jit(
        lambda y, t, m1, m2, ph, rb=rb, st=st: fit_final_stage(
            y, t, m1, m2, ph, row_block=rb, strategy=st).theta)
        for rb, st in ((0, None), (row_block, None), (row_block, "pallas"))}

    def timed(rb, st=None):
        def f():
            jax.block_until_ready(jitted[(rb, st)](d.y, d.t, my, mt, phi))
        return _time(f)

    t_whole = timed(0)
    t_chunk = timed(row_block)
    t_pallas = timed(row_block, "pallas")
    csv(f"final_stage_whole_n{n}_pphi{p_phi},{t_whole*1e6:.0f},baseline")
    csv(f"final_stage_chunked_n{n}_pphi{p_phi}_rb{row_block},"
        f"{t_chunk*1e6:.0f},ratio={t_chunk/max(t_whole, 1e-12):.2f}x")
    csv(f"final_stage_pallas_n{n}_pphi{p_phi}_rb{row_block},"
        f"{t_pallas*1e6:.0f},ratio={t_pallas/max(t_whole, 1e-12):.2f}x")
    return [(n, t_whole, t_chunk, t_pallas)]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale n=1M x p_phi=4")
    args = ap.parse_args(argv)
    if args.full:
        run(n=1_000_000, p=50, p_phi=4, row_block=8192)
    else:
        run()


if __name__ == "__main__":
    main()
