"""Effect-store refresh: incremental ingest vs full refit — the
daily-refresh workload (Amazon's "DML at Scale" motivation: new row
blocks arrive continuously; re-fitting from scratch is the dominant
cost).

Three measurements of the SAME day-k panel refresh:

  store_ingest_day    fold ONLY the day-k block into the standing
                      accumulators (one blocked pass over n_day rows)
                      and re-solve — the store's steady-state cost;
  store_ingest_small  the same with a 4x smaller arriving block —
                      the derived column reports the cost ratio, which
                      should track the block size, NOT total history
                      (ingest is O(new rows), refresh O(cells·p³));
  store_refit_full    rebuild from scratch over all k days of
                      concatenated rows and re-solve — the baseline
                      the store replaces.

The derived column of store_ingest_day also asserts the bitwise
contract (identity=PASS): the incrementally built panel must equal the
full rebuild bit-for-bit at these row-blocked shapes.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import CausalConfig
from repro.data.causal_dgp import make_causal_data
from repro.store import MomentStore
from repro.sweep.spec import SweepSpec


def _timeit(fn, reps: int = 3) -> float:
    fn()  # warm-up/compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _snapshot(store):
    return ([c.state for c in store._cols], store.seg_counts,
            store.n_total, store.version)


def _rollback(store, snap):
    states, seg_counts, n_total, version = snap
    for c, s in zip(store._cols, states):
        c.state = s
    store.seg_counts = seg_counts
    store.n_total = n_total
    store.version = version


def run(n_day=4096, days=5, p=10, n_segments=8, n_folds=3,
        row_block=1024, key=None, csv=print, reps=3):
    """Benchmark day-k refresh at ``days`` blocks of ``n_day`` rows."""
    key = key if key is not None else jax.random.PRNGKey(0)
    total = n_day * days
    data = make_causal_data(jax.random.fold_in(key, total), total, p,
                            effect=1.0, discrete_treatment=False)
    sids = jax.random.randint(jax.random.fold_in(key, 1), (total,), 0,
                              n_segments)
    cfg = CausalConfig(n_folds=n_folds, inference="none",
                       row_block=row_block, nuisance_t="ridge",
                       discrete_treatment=False)
    spec = SweepSpec(n_segments=n_segments, columns=(("dml", cfg),))
    tag = f"nday{n_day}_days{days}_p{p}_E{n_segments}"

    def block_kw(lo, hi):
        return dict(X=data.X[lo:hi], y=data.y[lo:hi], t=data.t[lo:hi],
                    segment_ids=sids[lo:hi])

    # standing store with days-1 days of history
    standing = MomentStore(spec, n_features=p, key=key)
    for d in range(days - 1):
        standing.ingest(**block_kw(d * n_day, (d + 1) * n_day))
    snap = _snapshot(standing)

    def ingest_day(lo, hi):
        _rollback(standing, snap)
        standing.ingest(**block_kw(lo, hi))
        jax.block_until_ready(standing.refresh().columns[0].thetas)

    t_day = _timeit(lambda: ingest_day(total - n_day, total), reps)
    small = n_day // 4
    t_small = _timeit(lambda: ingest_day(total - small, total), reps)

    # one reusable store rolled back to empty each rep, so the refit
    # measures compute, not per-instance jit compilation
    fresh = MomentStore(spec, n_features=p, key=key)
    zero = _snapshot(fresh)

    def refit_full():
        _rollback(fresh, zero)
        fresh.ingest(**block_kw(0, total))
        jax.block_until_ready(fresh.refresh().columns[0].thetas)

    t_full = _timeit(refit_full, reps)

    # the bitwise contract at these aligned shapes
    _rollback(standing, snap)
    standing.ingest(**block_kw(total - n_day, total))
    inc_theta = np.asarray(standing.refresh().columns[0].thetas)
    refit_full()
    full_theta = np.asarray(fresh.refresh().columns[0].thetas)
    identity = "PASS" if np.array_equal(inc_theta, full_theta) else "FAIL"

    csv(f"store_ingest_day_{tag},{t_day * 1e6:.1f},"
        f"identity={identity} speedup={t_full / t_day:.2f}x_vs_refit")
    csv(f"store_ingest_small_{tag},{t_small * 1e6:.1f},"
        f"block_scale={t_day / max(t_small, 1e-9):.2f}x_cost_for_4x_rows")
    csv(f"store_refit_full_{tag},{t_full * 1e6:.1f},n={total}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-day", type=int, default=4096)
    ap.add_argument("--days", type=int, default=5)
    ap.add_argument("--p", type=int, default=10)
    ap.add_argument("--segments", type=int, default=8)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(n_day=args.n_day, days=args.days, p=args.p,
        n_segments=args.segments)
