"""Row-sharded execution benchmark: the data-mesh path vs the
single-process chunked baseline, same process, 8 forced CPU devices.

Two workloads, the tentpole's acceptance rows:

  dist_sweep_*         a small estimator sweep end-to-end (trace +
                       compile + run — the per-column latency a job
                       submission pays), ``data_mesh=None`` vs the
                       ("hosts", "devices") mesh;
  dist_store_ingest_*  one incremental ``MomentStore.ingest`` block on
                       a warm store (jit-cached — steady-state
                       streaming cost), serial vs sharded.

Every row's derived column carries ``identity=PASS|FAIL`` — the
sharded panel/accumulators must be BITWISE the single-process result
("ordered" reduction); a FAIL here is a correctness regression, not a
perf one.

Run via ``run_subprocess`` from benchmarks/run.py: the forced
``--xla_force_host_platform_device_count=8`` must live in a CHILD
process, because jax pins the device count at first backend init and
every other bench section measures the 1-device baseline the >20%
gate was recorded against.
"""
from __future__ import annotations

import argparse
import os
import pathlib
import subprocess
import sys
import time

N_DEVICES = 8


def _time(fn, reps=3):
    fn()  # warm-up (and compile, where the callee caches)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(n=8192, p=8, n_segments=4, row_block=256, csv=print, reps=2):
    import jax
    import jax.numpy as jnp

    from repro.config import CausalConfig
    from repro.data.causal_dgp import make_causal_data
    from repro.runtime import make_data_mesh
    from repro.store import MomentStore
    from repro.sweep import SweepSpec, sweep

    dm = make_data_mesh()
    d = make_causal_data(jax.random.PRNGKey(42), n, p, effect=1.2)
    sids = jax.random.randint(jax.random.PRNGKey(9), (n,), 0, n_segments)
    key = jax.random.PRNGKey(0)
    cfg = CausalConfig(n_folds=3, inference="none", row_block=row_block)
    # Two dml columns (different fold counts): dml's weighted cell is
    # blocked END-TO-END, so the bitwise identity check holds at bench
    # scale, not just the canonical conformance shapes.  Estimators
    # with unblocked whole-array functionals (drlearner's ATE mean,
    # the metalearner cores) can drift 1-2 ulp at some data shapes
    # when XLA retiles those ambient reductions around shard_map — the
    # registry-wide certificate at canonical shapes lives in
    # tests/test_distributed_runtime.py.
    cfg5 = CausalConfig(n_folds=5, inference="none", row_block=row_block)
    spec = SweepSpec(n_segments=n_segments,
                     columns=(("dml", cfg), ("dml", cfg5)))
    kw = dict(X=d.X, y=d.y, t=d.t, segment_ids=sids, key=key)
    tag = f"n{n}_p{p}_E{n_segments}_{dm.label}"

    # -- sweep: end-to-end column latency (includes trace + compile) ----
    p_single = sweep(spec, **kw)
    p_dist = sweep(spec, data_mesh=dm, **kw)
    sweep_ok = all(
        bool(jnp.array_equal(c1.thetas, c2.thetas))
        and bool(jnp.array_equal(c1.ates, c2.ates))
        for c1, c2 in zip(p_single.columns, p_dist.columns))
    t_single = _time(lambda: sweep(spec, **kw), reps)
    t_dist = _time(lambda: sweep(spec, data_mesh=dm, **kw), reps)
    csv(f"dist_sweep_single_{tag},{t_single*1e6:.0f},baseline")
    csv(f"dist_sweep_sharded_{tag},{t_dist*1e6:.0f},"
        f"speedup={t_single/max(t_dist, 1e-12):.2f}x "
        f"identity={'PASS' if sweep_ok else 'FAIL'}")

    # -- store: steady-state incremental ingest (jit warm) --------------
    scfg = CausalConfig(n_folds=3, inference="none", row_block=row_block,
                        nuisance_t="ridge", discrete_treatment=False,
                        cate_features=1)
    sspec = SweepSpec(n_segments=n_segments, columns=(("dml", scfg),))
    blk = dict(X=d.X, y=d.y, t=d.t, segment_ids=sids)  # aligned: n % rb == 0
    ms_serial = MomentStore(sspec, n_features=p, key=key)
    ms_shard = MomentStore(sspec, n_features=p, key=key, data_mesh=dm)
    ms_serial.ingest(**blk)
    ms_shard.ingest(**blk)
    r1, r2 = ms_serial.refresh(), ms_shard.refresh()
    store_ok = all(
        bool(jnp.array_equal(c1.thetas, c2.thetas))
        for c1, c2 in zip(r1.columns, r2.columns))
    t_ser = _time(lambda: ms_serial.ingest(**blk), reps)
    t_shd = _time(lambda: ms_shard.ingest(**blk), reps)
    csv(f"dist_store_ingest_serial_{tag},{t_ser*1e6:.0f},baseline")
    csv(f"dist_store_ingest_sharded_{tag},{t_shd*1e6:.0f},"
        f"speedup={t_ser/max(t_shd, 1e-12):.2f}x "
        f"identity={'PASS' if store_ok else 'FAIL'}")
    return {"sweep": t_dist, "store": t_shd,
            "identity": sweep_ok and store_ok}


def run_subprocess(csv=print, smoke=True, timeout=1800):
    """Spawn this module with the forced 8-device CPU flag and feed its
    CSV stdout lines into ``csv`` (benchmarks/run.py's Recorder)."""
    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={N_DEVICES}")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (str(root), str(root / "src"),
                    os.environ.get("PYTHONPATH", "")) if p)
    cmd = [sys.executable, str(pathlib.Path(__file__).resolve())]
    if not smoke:
        cmd.append("--full")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=timeout)
    if proc.returncode != 0:
        tail = (proc.stdout + proc.stderr).strip().splitlines()[-3:]
        raise RuntimeError("bench_distributed subprocess failed: "
                           + " | ".join(tail))
    for line in proc.stdout.splitlines():
        if line.startswith("dist_"):
            csv(line)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="larger rows (n=32768)")
    args = ap.parse_args(argv)
    if args.full:
        run(n=32_768)
    else:
        run()


if __name__ == "__main__":
    main()
