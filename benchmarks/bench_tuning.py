"""Paper Fig. 5 / §5.2: distributed hyper-parameter tuning throughput —
one double-vmapped (trial x fold) population program vs the Ray-less
baseline of nested python loops over trials and folds."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core.crossfit import fold_ids, fold_weights, _oof_select
from repro.core.nuisance import make_ridge
from repro.core.tuning import tune_penalty


def sequential_grid(task, lams, X, y, n_folds, key):
    """Baseline: T x K separately-compiled fits, strictly sequential."""
    folds = fold_ids(key, X.shape[0], n_folds)
    W = fold_weights(folds, n_folds)
    best, best_score = None, float("inf")
    ridge = make_ridge(1.0)
    fit = jax.jit(ridge.fit)
    predict = jax.jit(ridge.predict)
    for lam in lams.tolist():
        preds = []
        for j in range(n_folds):
            st = {"beta": jnp.zeros((X.shape[1] + 1,), jnp.float32),
                  "lam": jnp.asarray(lam, jnp.float32)}
            st = fit(st, X, y, W[j])
            preds.append(predict(st, X))
        oof = _oof_select(jnp.stack(preds), folds)
        score = float(jnp.mean((oof - y) ** 2))
        if score < best_score:
            best, best_score = lam, score
    return best


def run(n, p, n_trials, n_folds, key=None, csv=print):
    key = key if key is not None else jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    X = jax.random.normal(ks[0], (n, p))
    beta = jax.random.normal(ks[1], (p,))
    y = X @ beta + jax.random.normal(ks[2], (n,))
    lams = jnp.logspace(-5, 1, n_trials).astype(jnp.float32)

    t0 = time.perf_counter()
    best_seq = sequential_grid("reg", lams, X, y, n_folds, key)
    t_seq = time.perf_counter() - t0

    tune_penalty("reg", lams, X, y, n_folds=n_folds, key=key)  # compile
    t0 = time.perf_counter()
    res = tune_penalty("reg", lams, X, y, n_folds=n_folds, key=key)
    t_par = time.perf_counter() - t0

    assert abs(res.best_value - best_seq) / best_seq < 1e-3, \
        (res.best_value, best_seq)
    csv(f"tuning_seq_T{n_trials}_K{n_folds},{t_seq*1e6:.0f},best={best_seq:.2e}")
    csv(f"tuning_pop_T{n_trials}_K{n_folds},{t_par*1e6:.0f},"
        f"speedup={t_seq/t_par:.2f}x")
    return t_seq, t_par


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--p", type=int, default=50)
    ap.add_argument("--trials", type=int, default=8)
    ap.add_argument("--folds", type=int, default=5)
    args = ap.parse_args(argv)
    run(args.n, args.p, args.trials, args.folds)


if __name__ == "__main__":
    main()
