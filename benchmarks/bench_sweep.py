"""Segment-parallel sweep runtime: serial loop of single fits vs the
batched panel — the many-cohorts workload (E effects per run) the paper
fans out on Ray and repro.sweep runs as batched SPMD programs.

Three executions of the SAME E-segment DML estimation:

  serial   ``sweep.serial_loop`` — one compiled program dispatched per
           segment cell, the practitioner's groupby loop (and the
           reference the panel is certified bitwise-identical against);
  cells    ``sweep(mode="cells")`` through the vmap executor — all E
           masked single fits as ONE batched program.  Identity with
           the serial loop is ASSERTED here (derived column), so the
           speedup is a pure scheduling win;
  segmented ``sweep(mode="segmented")`` — the one-pass segment×fold
           Gram kernels (LOO identity + MM logistic): a different
           execution of the same estimator (shared folds), so its
           derived column reports the deviation from the cells panel
           instead of bit-identity.

The acceptance bar (ISSUE 5): >= 3x over the serial loop at E=64 on
CPU — carried by the segmented path, with the cells path's scheduling
win reported alongside.  A fourth row re-runs the segmented path under
``row_block_strategy="pallas"`` (the fused seg_gram lowerings in the
fold-Gram / MM-loop / final-stage hot spots — ISSUE 7).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import CausalConfig
from repro.data.causal_dgp import make_causal_data
from repro.sweep import SweepSpec, serial_loop, sweep


def _timeit(fn, reps: int = 3) -> float:
    fn()  # warm-up/compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(n=16_384, p=10, n_segments=64, n_folds=3, row_block=1024,
        key=None, csv=print, reps=3):
    key = key if key is not None else jax.random.PRNGKey(0)
    data = make_causal_data(jax.random.fold_in(key, n), n, p, effect=1.0)
    sids = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0,
                              n_segments)
    # the row-blocked path: the scan barrier is where the serial == vmap
    # bit-identity contract is certified, so the identity column below
    # is a hard assertion, not a tolerance
    cfg = CausalConfig(n_folds=n_folds, inference="none",
                       row_block=row_block)
    spec = SweepSpec(n_segments=n_segments, columns=(("dml", cfg),))
    kw = dict(X=data.X, y=data.y, t=data.t, segment_ids=sids, key=key)
    tag = f"n{n}_p{p}_E{n_segments}"

    t_ser = _timeit(lambda: jax.block_until_ready(
        serial_loop("dml", cfg, n_segments=n_segments, **kw)["theta"]),
        reps)
    loop = serial_loop("dml", cfg, n_segments=n_segments, **kw)

    t_cells = _timeit(lambda: jax.block_until_ready(
        sweep(spec, executor="vmap", **kw).columns[0].thetas), reps)
    panel = sweep(spec, executor="vmap", **kw)
    identity = ("PASS" if np.array_equal(np.asarray(panel.columns[0].thetas),
                                         np.asarray(loop["theta"]))
                else "FAIL")

    t_seg = _timeit(lambda: jax.block_until_ready(
        sweep(spec, mode="segmented", **kw).columns[0].thetas), reps)
    seg = sweep(spec, mode="segmented", **kw)
    # segmented shares one fold draw across cells (a different execution
    # of the same estimator), so compare both paths against the DGP
    # truth instead of each other
    mae_seg = float(jnp.abs(seg.columns[0].ates - 1.0).mean())
    mae_cells = float(jnp.abs(panel.columns[0].ates - 1.0).mean())

    # segmented + row_block_strategy="pallas": the fused seg_gram
    # lowerings replace the one-hot einsums in the fold-Gram / MM-loop
    # / final-stage hot spots (tolerance-certified vs chunked by the
    # conformance suite)
    cfg_p = dataclasses.replace(cfg, row_block_strategy="pallas")
    spec_p = SweepSpec(n_segments=n_segments, columns=(("dml", cfg_p),))
    t_pal = _timeit(lambda: jax.block_until_ready(
        sweep(spec_p, mode="segmented", **kw).columns[0].thetas), reps)
    pal = sweep(spec_p, mode="segmented", **kw)
    mae_pal = float(jnp.abs(pal.columns[0].ates - 1.0).mean())

    csv(f"sweep_serial_loop_{tag},{t_ser*1e6:.0f},baseline")
    csv(f"sweep_cells_vmap_{tag},{t_cells*1e6:.0f},"
        f"speedup={t_ser/t_cells:.2f}x identity={identity} "
        f"mae={mae_cells:.3f}")
    csv(f"sweep_segmented_{tag},{t_seg*1e6:.0f},"
        f"speedup={t_ser/t_seg:.2f}x mae={mae_seg:.3f}")
    csv(f"sweep_segmented_pallas_{tag},{t_pal*1e6:.0f},"
        f"speedup={t_ser/t_pal:.2f}x mae={mae_pal:.3f}")
    return {"serial": t_ser, "cells": t_cells, "segmented": t_seg,
            "segmented_pallas": t_pal, "identity": identity}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="industrial-scale rows (slow on CPU)")
    args = ap.parse_args(argv)
    if args.full:
        run(n=65_536, p=50, n_segments=64, n_folds=5)
    else:
        run()


if __name__ == "__main__":
    main()
