"""Orthogonal-IV workload benchmarks: the fourth paper-parallelized
estimator family on the same harness as bench_crossfit /
bench_inference.

  iv_orthoiv_fit      one full OrthoIV fit (3 cross-fit nuisances + the
                      instrumented final stage) — the per-fit cost the
                      paper's catalogue scales;
  iv_driv_fit         one full DRIV fit (4 nuisances + pseudo-outcome
                      regression);
  iv_bootstrap_seq /  B weighted OrthoIV refits through the serial
  iv_bootstrap_vmap   (Ray-less loop) vs vmap (one batched program)
                      executors — the mechanism speedup on the IV
                      moment.

Entries are gated by the CI bench-regression gate (prefix "iv" in
benchmarks/compare.py).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.config import CausalConfig
from repro.core.iv import DRIV, OrthoIV
from repro.data.causal_dgp import make_iv_data
from repro.inference import make_executor
from repro.inference.bootstrap import replicate_keys


def _time(fn, reps: int = 1) -> float:
    fn()  # warm-up / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps


def time_iv_bootstrap(est: OrthoIV, ctx, B: int, executor: str,
                      key) -> float:
    """Wall-clock for B OrthoIV bootstrap replicates through one
    executor (warm; isolates dispatch mechanism, not compile)."""
    from repro.inference.bootstrap import (bootstrap_weights,
                                           iv_theta_once)
    exe = make_executor(executor)
    keys = replicate_keys(key, B)
    n_folds = est.cfg.n_folds

    def replicate(kb, XW, y, t, z, phi):
        kw, kfit = jax.random.split(kb)
        w = bootstrap_weights(kw, XW.shape[0], "pairs")
        return iv_theta_once(est.nuis_y, est.nuis_t, est.nuis_z,
                             n_folds, XW, y, t, z, phi, kfit, w,
                             with_se=False)

    def run():
        jax.block_until_ready(
            exe.map(replicate, keys, ctx.XW, ctx.y, ctx.t, ctx.z,
                    ctx.phi)["theta"])

    return _time(run)


def run(sizes=(5_000,), p=20, B=16, n_folds=5, key=None, csv=print):
    key = key if key is not None else jax.random.PRNGKey(0)
    rows = []
    for n in sizes:
        data = make_iv_data(jax.random.fold_in(key, n), n, p,
                            effect=1.0, compliance=0.7)
        cfg = CausalConfig(n_folds=n_folds, inference="none")
        est = OrthoIV(cfg)
        driv = DRIV(cfg)

        def fit_once():
            r = est.fit(data.y, data.t, data.z, data.X, key=key)
            jax.block_until_ready(r.theta)
            return r

        t_fit = _time(fit_once)
        res = fit_once()
        err = abs(res.late - data.true_late)
        csv(f"iv_orthoiv_fit_n{n}_p{p},{t_fit*1e6:.0f},"
            f"late_err={err:.4f}")

        def driv_once():
            r = driv.fit(data.y, data.t, data.z, data.X, key=key)
            jax.block_until_ready(r.theta)

        t_driv = _time(driv_once)
        csv(f"iv_driv_fit_n{n}_p{p},{t_driv*1e6:.0f},"
            f"ratio={t_driv/t_fit:.2f}x")

        ctx = res.fit_ctx
        kb = jax.random.fold_in(key, 0x1b00)
        t_seq = time_iv_bootstrap(est, ctx, B, "serial", kb)
        t_vec = time_iv_bootstrap(est, ctx, B, "vmap", kb)
        csv(f"iv_bootstrap_seq_n{n}_p{p}_B{B},{t_seq*1e6:.0f},baseline")
        csv(f"iv_bootstrap_vmap_n{n}_p{p}_B{B},{t_vec*1e6:.0f},"
            f"speedup={t_seq/t_vec:.2f}x")
        rows.append((n, t_fit, t_driv, t_seq, t_vec))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale n sweep with B=200")
    args = ap.parse_args(argv)
    if args.full:
        run(sizes=(10_000, 100_000), p=500, B=200)
    else:
        run(sizes=(5_000,), p=20, B=16)


if __name__ == "__main__":
    main()
